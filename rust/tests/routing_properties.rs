//! Property tests over the host-side routing mirror (no XLA needed):
//! capacity, slot uniqueness, drop accounting, prototype disjointness,
//! cross-checks between top-k and prototyping, and bitwise equivalence
//! between the naive `route()` reference and the allocation-free
//! `RoutingEngine`.

use m6t::config::Routing;
use m6t::moe::router::softmax_gates;
use m6t::moe::{route, RouterSpec, RoutingEngine};
use m6t::testing::{check, gen, route_outputs_bitwise_eq as diff};
use m6t::util::rng::Rng;

fn random_spec(rng: &mut Rng, b: m6t::testing::Bounds) -> (Vec<f32>, usize, RouterSpec) {
    let (tokens, experts, capacity) = gen::routing_shape(rng, b);
    let logits: Vec<f32> = (0..tokens * experts).map(|_| rng.normal() as f32).collect();
    let k = [1u32, 2, 4][(rng.below(3)) as usize].min(experts as u32);
    let proto = rng.below(2) == 0 && experts % (k as usize) == 0;
    let routing = if proto && k > 1 {
        Routing::Prototype(k)
    } else {
        Routing::TopK(k.min(experts as u32))
    };
    let z = routing.prototypes() as usize;
    let gates = softmax_gates(&logits, tokens, experts, z);
    (gates, tokens, RouterSpec { routing, num_experts: experts, capacity })
}

#[test]
fn prop_capacity_never_exceeded() {
    check("capacity", 200, |rng, b| {
        let (gates, tokens, spec) = random_spec(rng, b);
        let out = route(&gates, tokens, &spec);
        for (e, &l) in out.load.iter().enumerate() {
            if l as usize > spec.capacity {
                return Err(format!("expert {e} load {l} > capacity {}", spec.capacity));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_slots_unique_and_in_range() {
    check("slots", 200, |rng, b| {
        let (gates, tokens, spec) = random_spec(rng, b);
        let out = route(&gates, tokens, &spec);
        let mut seen = std::collections::HashSet::new();
        for a in &out.assignments {
            if a.position >= spec.capacity {
                return Err(format!("assignment slot {} >= C {}", a.position, spec.capacity));
            }
            if !seen.insert((a.expert, a.position)) {
                return Err(format!("duplicate slot ({}, {})", a.expert, a.position));
            }
            if a.token >= tokens || a.expert >= spec.num_experts {
                return Err("index out of range".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_drop_accounting_balances() {
    check("drops", 200, |rng, b| {
        let (gates, tokens, spec) = random_spec(rng, b);
        let out = route(&gates, tokens, &spec);
        let kept: u32 = out.load.iter().sum();
        let expected = (tokens as u32) * spec.routing.k().min(spec.num_experts as u32);
        if kept + out.dropped != expected {
            return Err(format!(
                "kept {kept} + dropped {} != {} ({:?})",
                out.dropped, expected, spec.routing
            ));
        }
        if out.assignments.len() != kept as usize {
            return Err("assignment count != kept-load sum".into());
        }
        Ok(())
    });
}

#[test]
fn prop_prototype_assignments_stay_in_group() {
    check("proto-groups", 150, |rng, b| {
        let (tokens, experts, capacity) = gen::routing_shape(rng, b);
        let experts = if experts % 2 == 1 { experts + 1 } else { experts };
        let logits: Vec<f32> = (0..tokens * experts).map(|_| rng.normal() as f32).collect();
        let gates = softmax_gates(&logits, tokens, experts, 2);
        let spec = RouterSpec {
            routing: Routing::Prototype(2),
            num_experts: experts,
            capacity,
        };
        let out = route(&gates, tokens, &spec);
        let f = experts / 2;
        // each token has at most one assignment per prototype group
        for t in 0..tokens {
            let mut per_group = [0usize; 2];
            for a in out.assignments.iter().filter(|a| a.token == t) {
                per_group[a.expert / f] += 1;
            }
            if per_group[0] > 1 || per_group[1] > 1 {
                return Err(format!("token {t} routed twice in one group"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_engine_matches_reference() {
    // one engine across all cases: also exercises scratch reuse over
    // wildly varying (tokens, experts, k) shapes
    let mut engine = RoutingEngine::new();
    check("engine-parity", 250, |rng, b| {
        let (gates, tokens, spec) = random_spec(rng, b);
        let expect = route(&gates, tokens, &spec);
        let got = engine.route(&gates, tokens, &spec);
        diff(&got, &expect)
    });
}

#[test]
fn prop_engine_matches_reference_tight_capacity_and_k_eq_e() {
    // the edge cases the issue calls out explicitly: capacity 1 (heavy
    // drops), ample capacity, k == E (dense top-E), and full prototyping
    // (z == E, one expert per group)
    let mut engine = RoutingEngine::new();
    check("engine-parity-edges", 120, |rng, b| {
        let (tokens, experts, _) = gen::routing_shape(rng, b);
        let logits: Vec<f32> = (0..tokens * experts).map(|_| rng.normal() as f32).collect();
        let specs = [
            RouterSpec {
                routing: Routing::TopK(experts as u32),
                num_experts: experts,
                capacity: 1,
            },
            RouterSpec {
                routing: Routing::TopK(experts as u32),
                num_experts: experts,
                capacity: tokens,
            },
            RouterSpec {
                routing: Routing::Prototype(experts as u32),
                num_experts: experts,
                capacity: 1,
            },
        ];
        for spec in specs {
            let z = spec.routing.prototypes() as usize;
            let gates = softmax_gates(&logits, tokens, experts, z);
            let expect = route(&gates, tokens, &spec);
            let got = engine.route(&gates, tokens, &spec);
            diff(&got, &expect).map_err(|e| format!("{:?}: {e}", spec.routing))?;
        }
        Ok(())
    });
}

#[test]
fn prop_top1_and_1proto_identical() {
    // TopK(1) and Prototype(1) are the same algorithm — and since the
    // top-1 gate-parity fix (no renormalization at k = 1) their combine
    // gates agree bitwise too, not just their load/drop counts
    check("top1-eq-1top1", 100, |rng, b| {
        let (tokens, experts, capacity) = gen::routing_shape(rng, b);
        let logits: Vec<f32> = (0..tokens * experts).map(|_| rng.normal() as f32).collect();
        let gates = softmax_gates(&logits, tokens, experts, 1);
        let a = route(
            &gates,
            tokens,
            &RouterSpec { routing: Routing::TopK(1), num_experts: experts, capacity },
        );
        let b2 = route(
            &gates,
            tokens,
            &RouterSpec { routing: Routing::Prototype(1), num_experts: experts, capacity },
        );
        diff(&a, &b2)
    });
}

#[test]
fn prop_ample_capacity_drops_nothing() {
    check("ample", 100, |rng, b| {
        let (tokens, experts, _) = gen::routing_shape(rng, b);
        let logits: Vec<f32> = (0..tokens * experts).map(|_| rng.normal() as f32).collect();
        let gates = softmax_gates(&logits, tokens, experts, 1);
        let spec = RouterSpec {
            routing: Routing::TopK(1),
            num_experts: experts,
            capacity: tokens, // every token fits in any single expert
        };
        let out = route(&gates, tokens, &spec);
        if out.dropped != 0 {
            return Err(format!("dropped {} with ample capacity", out.dropped));
        }
        Ok(())
    });
}

#[test]
fn prop_topk_clamps_k_beyond_experts() {
    // k > E degenerates to dense top-E with exact drop accounting — the
    // old code hit `debug_assert!(best != usize::MAX)` here
    check("k-clamp", 100, |rng, b| {
        let (tokens, experts, capacity) = gen::routing_shape(rng, b);
        let logits: Vec<f32> = (0..tokens * experts).map(|_| rng.normal() as f32).collect();
        let gates = softmax_gates(&logits, tokens, experts, 1);
        let k = experts as u32 + 1 + rng.below(8) as u32;
        let spec = RouterSpec { routing: Routing::TopK(k), num_experts: experts, capacity };
        let out = route(&gates, tokens, &spec);
        let kept: u32 = out.load.iter().sum();
        let expected = (tokens * experts) as u32;
        if kept + out.dropped != expected {
            return Err(format!(
                "k={k} E={experts}: kept {kept} + dropped {} != {expected}",
                out.dropped
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_cv_reflects_skew() {
    check("cv", 60, |_rng, _b| {
        let tokens = 64;
        let experts = 8;
        // uniform round-robin gates
        let mut uniform = vec![0.0f32; tokens * experts];
        for t in 0..tokens {
            uniform[t * experts + (t % experts)] = 1.0;
        }
        // skewed: everything on expert 0
        let mut skew = vec![0.0f32; tokens * experts];
        for t in 0..tokens {
            skew[t * experts] = 1.0;
        }
        let spec = RouterSpec {
            routing: Routing::TopK(1),
            num_experts: experts,
            capacity: tokens,
        };
        let cv_u = route(&uniform, tokens, &spec).cv();
        let cv_s = route(&skew, tokens, &spec).cv();
        if cv_u >= cv_s {
            return Err(format!("cv uniform {cv_u} >= cv skew {cv_s}"));
        }
        Ok(())
    });
}
