//! Integration tests over the native backend: registry contract, routing
//! accounting, training dynamics, paired eval, checkpoint round-trip, and
//! the paper's qualitative balance/quality claims — all with **zero
//! artifacts on disk** (see DESIGN.md §Backends; the PJRT twin of this
//! suite needs `--features pjrt` plus a vendored xla crate and a compiled
//! artifact set).

use m6t::coordinator::{Checkpoint, TrainOptions, Trainer};
use m6t::data::{Batcher, Split};
use m6t::runtime::{Backend, BackendProvider, NativeProvider};

fn quiet(steps: i64) -> TrainOptions {
    TrainOptions { steps, seed: 42, verbose: false, ..Default::default() }
}

#[test]
fn registry_loads_and_is_consistent() {
    let p = NativeProvider::new();
    let names = p.names();
    assert!(names.len() >= 24, "only {} variants", names.len());
    for name in &names {
        let v = p.info(name).expect("info");
        assert_eq!(v.n_state, v.n_params + v.n_opt, "{name}");
        assert_eq!(v.state_leaves.len(), v.n_state, "{name}");
        // native param accounting is the config's own closed form
        assert_eq!(v.config.param_count(), v.param_count, "{name}");
        // capacity formula agreement registry<->config (Eq. 2)
        assert_eq!(v.config.capacity(), v.capacity, "{name}");
        // the native state layout: loss-law params + per-layer router bias
        assert_eq!(v.state_leaves[0].elements(), 3, "{name}");
        assert_eq!(
            v.state_leaves[1].elements(),
            v.config.layers * v.config.num_experts,
            "{name}"
        );
    }
    // the figure/table drivers' variant names must all resolve
    for required in [
        "base-sim",
        "base-sim-aux",
        "base-sim-top2-capk",
        "base-sim-top2-cap1",
        "base-sim-2top1-cap1",
        "base-sim-moeattn",
        "deep-sim",
        "large-sim",
        "xlarge-sim-2top1-cap1",
        "e2e-100m",
        "base-top2",
        "base-sim-real",
        "base-sim-real-af",
    ] {
        assert!(names.iter().any(|n| n == required), "missing {required}");
    }
}

#[test]
fn native_end_to_end() {
    let provider = NativeProvider::new();
    let backend = provider.load("base-sim").expect("load base-sim");

    check_init_determinism(backend.as_ref());
    check_step_dynamics(backend.as_ref());
    check_eval_pairing(backend.as_ref());
    check_cv_plausible(backend.as_ref());
    check_checkpoint_roundtrip(&provider);
}

fn check_init_determinism(rt: &dyn Backend) {
    let a = rt.init_state(7).unwrap();
    let b = rt.init_state(7).unwrap();
    let c = rt.init_state(8).unwrap();
    let ha = rt.state_to_host(&a).unwrap();
    let hb = rt.state_to_host(&b).unwrap();
    let hc = rt.state_to_host(&c).unwrap();
    assert_eq!(ha, hb, "same seed, same init");
    assert_ne!(ha, hc, "different seed, different init");
    // regression: the old `seed as u32` truncation made seeds differing
    // only in their upper 32 bits collide to the same init
    let d = rt.init_state(7 | (1 << 32)).unwrap();
    let hd = rt.state_to_host(&d).unwrap();
    assert_ne!(ha, hd, "upper seed bits must vary the init stream");
}

fn check_step_dynamics(rt: &dyn Backend) {
    let cfg = &rt.info().config;
    let capacity = rt.info().capacity;
    let mut state = rt.init_state(42).unwrap();
    let mut batcher = Batcher::for_config(cfg, Split::Train, 42);
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for i in 0..8 {
        let batch = batcher.next_batch();
        let (next, stats) = rt.step(state, &batch).unwrap();
        state = next;
        if i == 0 {
            first = stats.loss;
        }
        last = stats.loss;
        // kept + dropped tokens account for every routed token per layer
        let kept: f64 = stats.load.iter().map(|&x| x as f64).sum();
        let dropped: f64 = stats.total_dropped();
        let expected = (cfg.layers * cfg.tokens_per_batch() * cfg.routing.k() as usize) as f64;
        assert_eq!(kept + dropped, expected, "step {i}");
        assert!(stats.loss.is_finite());
        assert!(stats.grad_norm > 0.0);
        // per-expert load never exceeds capacity
        assert!(stats.load.iter().all(|&l| (l as usize) <= capacity));
        // the simulated step latency is a real, positive model output
        assert!(stats.sim_step_ms > 0.0 && stats.sim_step_ms.is_finite());
    }
    assert!(last <= first + 0.05, "loss exploded: {first} -> {last}");
    assert!(last < first, "8 steps of power-law descent must reduce loss");
}

fn check_eval_pairing(rt: &dyn Backend) {
    let state = rt.init_state(1).unwrap();
    let mut b1 = Batcher::for_config(&rt.info().config, Split::Eval, 42);
    let mut b2 = Batcher::for_config(&rt.info().config, Split::Eval, 42);
    let (nll1, c1) = rt.eval(&state, &b1.next_batch()).unwrap();
    let (nll2, c2) = rt.eval(&state, &b2.next_batch()).unwrap();
    assert_eq!(nll1, nll2);
    assert_eq!(c1, c2);
    // PPL at init is near the uniform prior over the vocab
    let ppl = (nll1 / c1).exp();
    let vocab = rt.info().config.vocab_size as f64;
    assert!(ppl > vocab * 0.3 && ppl < vocab * 3.0, "init ppl {ppl} vs vocab {vocab}");
}

fn check_cv_plausible(rt: &dyn Backend) {
    let state = rt.init_state(3).unwrap();
    let mut batcher = Batcher::for_config(&rt.info().config, Split::Train, 3);
    let (_, stats) = rt.step(state, &batcher.next_batch()).unwrap();
    let cv = stats.cv_per_layer();
    assert_eq!(cv.len(), rt.info().config.layers);
    for (l, c) in cv.iter().enumerate() {
        assert!(c.is_finite() && *c >= 0.0, "layer {l} cv {c}");
        assert!(*c < 4.0, "layer {l} cv {c} absurdly high");
    }
}

fn check_checkpoint_roundtrip(provider: &NativeProvider) {
    let trainer = Trainer::new(provider.load("base-sim").unwrap(), quiet(3));
    let (out1, state) = trainer.train().unwrap();
    let ck = trainer.snapshot(&state).unwrap();
    let path = std::env::temp_dir().join("m6t-native-int-ckpt.bin");
    ck.save(&path).unwrap();
    let ck2 = Checkpoint::load(&path).unwrap();
    assert_eq!(ck2.step, out1.final_state_step);
    let restored = trainer.restore(&ck2).unwrap();
    // continuing from the checkpoint reproduces the same next loss as
    // continuing in-memory (bitwise determinism of the whole stack)
    let cfg = &trainer.info().config;
    let mut batcher = Batcher::for_config(cfg, Split::Train, 42);
    batcher.seek(state.step as u64 * cfg.batch as u64);
    let batch = batcher.next_batch();
    let (_, stats_mem) = trainer.backend.step(state, &batch).unwrap();
    let (_, stats_ck) = trainer.backend.step(restored, &batch).unwrap();
    assert_eq!(stats_mem.loss, stats_ck.loss);
    assert_eq!(stats_mem.load, stats_ck.load);
    let _ = std::fs::remove_file(path);
}

/// Resume idempotency (found in PR 4 review): resuming the *same*
/// checkpoint twice must not double-log the overlapping step range — the
/// metrics JSONL step column stays strictly monotone because the
/// append-open drops records the resumed run is about to re-execute.
#[test]
fn resuming_the_same_checkpoint_twice_keeps_the_step_column_monotone() {
    let provider = NativeProvider::new();
    let dir = std::env::temp_dir().join("m6t-resume-idempotency-test");
    let _ = std::fs::remove_dir_all(&dir);
    let metrics_dir = dir.join("metrics").to_string_lossy().into_owned();
    let opts = TrainOptions {
        steps: 4,
        seed: 42,
        verbose: false,
        metrics_dir: Some(metrics_dir.clone()),
        ..Default::default()
    };
    let trainer = Trainer::new(provider.load("base-sim").unwrap(), opts);
    let (_, state) = trainer.train().unwrap();
    let ck = trainer.snapshot(&state).unwrap();
    let ck_path = dir.join("ck.bin");
    ck.save(&ck_path).unwrap();

    let sink = std::path::Path::new(&metrics_dir).join("base-sim.jsonl");
    let steps_in = |path: &std::path::Path| -> Vec<i64> {
        std::fs::read_to_string(path)
            .unwrap()
            .lines()
            .map(|l| {
                m6t::util::json::parse(l)
                    .unwrap()
                    .get("step")
                    .and_then(|s| s.as_i64())
                    .expect("record has a step")
            })
            .collect()
    };
    assert_eq!(steps_in(&sink), vec![0, 1, 2, 3]);

    // resume the SAME checkpoint twice; each resume re-runs steps 4..6
    for round in 0..2 {
        let loaded = Checkpoint::load(&ck_path).unwrap();
        let resumed = trainer.restore(&loaded).unwrap();
        trainer.train_from(resumed).unwrap();
        let steps = steps_in(&sink);
        assert_eq!(
            steps,
            vec![0, 1, 2, 3, 4, 5, 6, 7],
            "resume round {round}: overlapping range double-logged"
        );
        let mut sorted = steps.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted, steps, "resume round {round}: step column not monotone");
    }
    let _ = std::fs::remove_dir_all(dir);
}

/// Fig 1's finding: the aux loss buys balance (lower c_v), not quality.
#[test]
fn aux_loss_balances_but_does_not_win() {
    let provider = NativeProvider::new();
    let steps = 60;
    let (base_out, _) = Trainer::new(provider.load("base-sim").unwrap(), quiet(steps))
        .train()
        .unwrap();
    let (aux_out, _) = Trainer::new(provider.load("base-sim-aux").unwrap(), quiet(steps))
        .train()
        .unwrap();
    let layers = provider.info("base-sim").unwrap().config.layers;
    let tail_cv = |log: &m6t::metrics::RunLog| -> f64 {
        (0..layers).map(|l| log.tail_cv(l, 10)).sum::<f64>() / layers as f64
    };
    let cv_base = tail_cv(&base_out.log);
    let cv_aux = tail_cv(&aux_out.log);
    assert!(
        cv_aux < cv_base * 0.7,
        "aux loss must visibly balance the load: base {cv_base:.3} aux {cv_aux:.3}"
    );
    assert!(
        aux_out.log.tail_loss(10) >= base_out.log.tail_loss(10) - 0.01,
        "balance must not buy quality (paper Fig 1)"
    );
}

/// Fig 3's finding at small scale: k = 2 beats k = 1; limited capacity
/// drops tokens while full capacity does not.
#[test]
fn top2_beats_top1_and_capacity_governs_drops() {
    let provider = NativeProvider::new();
    let steps = 60;
    let (top1, _) = Trainer::new(provider.load("base-sim").unwrap(), quiet(steps))
        .train()
        .unwrap();
    let (top2_capk, _) =
        Trainer::new(provider.load("base-sim-top2-capk").unwrap(), quiet(steps))
            .train()
            .unwrap();
    let (top2_cap1, _) =
        Trainer::new(provider.load("base-sim-top2-cap1").unwrap(), quiet(steps))
            .train()
            .unwrap();
    assert!(
        top2_capk.log.tail_loss(10) < top1.log.tail_loss(10),
        "top-2 (capacity kx) must out-train top-1: {} vs {}",
        top2_capk.log.tail_loss(10),
        top1.log.tail_loss(10)
    );
    let drops_cap1: f64 =
        top2_cap1.log.records.iter().map(|r| r.dropped).sum::<f64>();
    assert!(drops_cap1 > 0.0, "capacity 1x with k=2 must drop tokens");
}
