//! Integration tests over the real artifacts: manifest contract, PJRT
//! execution, training dynamics, checkpoint round-trip, c_v plausibility.
//!
//! Requires `make artifacts` (skipped gracefully if absent). The PJRT
//! client is `Rc`-based (not `Sync`), so all engine-backed checks run
//! sequentially inside one test with a single ~30 s compilation.

use m6t::coordinator::{Checkpoint, TrainOptions, Trainer};
use m6t::data::{Batcher, Split};
use m6t::runtime::{Engine, Manifest, VariantRuntime};

fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

#[test]
fn manifest_loads_and_is_consistent() {
    if !artifacts_present() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let m = Manifest::load("artifacts").expect("manifest");
    assert!(m.variants.len() >= 20, "only {} variants", m.variants.len());
    for (name, v) in &m.variants {
        assert_eq!(v.n_state, v.n_params + v.n_opt, "{name}");
        assert_eq!(v.state_leaves.len(), v.n_state, "{name}");
        // rust param accounting must match python's (through the manifest)
        assert_eq!(v.config.param_count(), v.param_count, "{name}");
        // param leaves alone must hold exactly param_count elements
        let n: usize = v.state_leaves[..v.n_params].iter().map(|l| l.elements()).sum();
        assert_eq!(n as u64, v.param_count, "{name}");
        // capacity formula agreement python<->rust
        assert_eq!(v.config.capacity(), v.capacity, "{name}");
    }
}

#[test]
fn engine_end_to_end() {
    if !artifacts_present() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::cpu().expect("pjrt cpu client");
    let manifest = Manifest::load("artifacts").expect("manifest");
    let info = manifest.variant("base-sim").expect("base-sim");
    let rt = engine.load(info).expect("compile base-sim");

    check_init_determinism(&rt);
    check_step_dynamics(&rt);
    check_eval_pairing(&rt);
    check_cv_plausible(&rt);
    check_checkpoint_roundtrip(&engine, rt);
}

fn check_init_determinism(rt: &VariantRuntime) {
    let a = rt.init_state(7).unwrap();
    let b = rt.init_state(7).unwrap();
    let c = rt.init_state(8).unwrap();
    let ha = rt.state_to_host(&a).unwrap();
    let hb = rt.state_to_host(&b).unwrap();
    let hc = rt.state_to_host(&c).unwrap();
    assert_eq!(ha, hb, "same seed, same init");
    assert_ne!(ha, hc, "different seed, different init");
}

fn check_step_dynamics(rt: &VariantRuntime) {
    let cfg = &rt.info.config;
    let mut state = rt.init_state(42).unwrap();
    let mut batcher = Batcher::for_config(cfg, Split::Train, 42);
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for i in 0..8 {
        let batch = batcher.next_batch();
        let (next, stats) = rt.step(state, &batch).unwrap();
        state = next;
        if i == 0 {
            first = stats.loss;
        }
        last = stats.loss;
        // kept + dropped tokens account for every routed token per layer
        let kept: f64 = stats.load.iter().map(|&x| x as f64).sum();
        let dropped: f64 = stats.total_dropped();
        let expected = (cfg.layers * cfg.tokens_per_batch() * cfg.routing.k() as usize) as f64;
        assert_eq!(kept + dropped, expected, "step {i}");
        assert!(stats.loss.is_finite());
        assert!(stats.grad_norm > 0.0);
        // per-expert load never exceeds capacity
        assert!(stats.load.iter().all(|&l| (l as usize) <= rt.info.capacity));
    }
    assert!(last <= first + 0.05, "loss exploded: {first} -> {last}");
}

fn check_eval_pairing(rt: &VariantRuntime) {
    let state = rt.init_state(1).unwrap();
    let mut b1 = Batcher::for_config(&rt.info.config, Split::Eval, 42);
    let mut b2 = Batcher::for_config(&rt.info.config, Split::Eval, 42);
    let (nll1, c1) = rt.eval(&state, &b1.next_batch()).unwrap();
    let (nll2, c2) = rt.eval(&state, &b2.next_batch()).unwrap();
    assert_eq!(nll1, nll2);
    assert_eq!(c1, c2);
    // PPL at init is near the uniform prior over the vocab
    let ppl = (nll1 / c1).exp();
    let vocab = rt.info.config.vocab_size as f64;
    assert!(ppl > vocab * 0.3 && ppl < vocab * 3.0, "init ppl {ppl}");
}

fn check_cv_plausible(rt: &VariantRuntime) {
    let state = rt.init_state(3).unwrap();
    let mut batcher = Batcher::for_config(&rt.info.config, Split::Train, 3);
    let (_, stats) = rt.step(state, &batcher.next_batch()).unwrap();
    let cv = stats.cv_per_layer();
    assert_eq!(cv.len(), rt.info.config.layers);
    for (l, c) in cv.iter().enumerate() {
        assert!(c.is_finite() && *c >= 0.0, "layer {l} cv {c}");
        assert!(*c < 4.0, "layer {l} cv {c} absurdly high");
    }
}

fn check_checkpoint_roundtrip(engine: &Engine, rt: VariantRuntime) {
    let opts = TrainOptions { steps: 3, seed: 42, verbose: false, ..Default::default() };
    let trainer = Trainer::new(engine, rt, opts);
    let (out1, state) = trainer.train().unwrap();
    let ck = trainer.snapshot(&state).unwrap();
    let path = std::env::temp_dir().join("m6t-int-ckpt.bin");
    ck.save(&path).unwrap();
    let ck2 = Checkpoint::load(&path).unwrap();
    assert_eq!(ck2.step, out1.final_state_step);
    let restored = trainer.restore(&ck2).unwrap();
    // continuing from the checkpoint reproduces the same next loss as
    // continuing in-memory (bitwise determinism of the whole stack)
    let mut batcher = Batcher::for_config(&trainer.runtime.info.config, Split::Train, 42);
    batcher.seek(state.step as u64 * trainer.runtime.info.config.batch as u64);
    let batch = batcher.next_batch();
    let (_, stats_mem) = trainer.runtime.step(state, &batch).unwrap();
    let (_, stats_ck) = trainer.runtime.step(restored, &batch).unwrap();
    assert_eq!(stats_mem.loss, stats_ck.loss);
    let _ = std::fs::remove_file(path);
}
