//! Property tests over the cluster cost model: step time monotone in
//! capacity, k top-1 prototyping never slower than top-k at equal k (the
//! Table-2 asymmetry), and the one-point anchor calibration converging on
//! arbitrary targets.

use m6t::cluster::{simulate_step, table2_hardware, HardwareModel};
use m6t::config::{paper, CapacityMode, Routing};
use m6t::testing::check;

#[test]
fn prop_step_time_monotone_in_capacity() {
    check("capacity-monotone", 60, |rng, _b| {
        let mut cfg = if rng.below(2) == 0 { paper::base() } else { paper::ten_b() };
        cfg.capacity_factor = 0.5 + rng.uniform();
        let hw = table2_hardware();
        let k = [1u32, 2, 4][rng.below(3) as usize];
        let routing = Routing::TopK(k);
        let t_small = simulate_step(&cfg, routing, CapacityMode::TimesK, &hw).total_ms();
        let mut bigger = cfg.clone();
        bigger.capacity_factor = cfg.capacity_factor + 0.01 + rng.uniform() * 2.0;
        let t_big = simulate_step(&bigger, routing, CapacityMode::TimesK, &hw).total_ms();
        if t_big + 1e-9 < t_small {
            return Err(format!(
                "step time fell as capacity grew: γ {:.3} -> {:.3} gave {t_small:.2} -> {t_big:.2} ms",
                cfg.capacity_factor, bigger.capacity_factor
            ));
        }
        // the 1x -> kx capacity jump can only slow the step down too
        let limited = simulate_step(&cfg, routing, CapacityMode::Times1, &hw).total_ms();
        let full = simulate_step(&cfg, routing, CapacityMode::TimesK, &hw).total_ms();
        if full + 1e-9 < limited {
            return Err(format!("kx ({full:.2}) faster than 1x ({limited:.2}) at k={k}"));
        }
        Ok(())
    });
}

#[test]
fn prop_prototyping_never_slower_at_equal_k() {
    check("proto-not-slower", 60, |rng, _b| {
        let mut cfg = if rng.below(2) == 0 { paper::base() } else { paper::ten_b() };
        cfg.capacity_factor = 0.75 + rng.uniform();
        let hw = table2_hardware();
        for k in [2u32, 4] {
            for mode in [CapacityMode::TimesK, CapacityMode::Times1] {
                let topk = simulate_step(&cfg, Routing::TopK(k), mode, &hw).total_ms();
                let proto = simulate_step(&cfg, Routing::Prototype(k), mode, &hw).total_ms();
                if proto > topk + 1e-9 {
                    return Err(format!(
                        "{} k={k} {:?}: prototyping {proto:.2} ms slower than top-k {topk:.2} ms",
                        cfg.name, mode
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_anchor_calibration_converges() {
    check("calibration", 40, |rng, _b| {
        let cfg = paper::base();
        let routing = Routing::TopK(2);
        let mode = CapacityMode::Times1;
        // the model's floor for this cell: zero framework overhead
        let mut floor_hw = HardwareModel::v100();
        floor_hw.framework_layer = 0.0;
        let floor = simulate_step(&cfg, routing, mode, &floor_hw).total_ms();
        let target = floor + 1.0 + rng.uniform() * 400.0;
        let hw = HardwareModel::v100().calibrated_to(&cfg, routing, mode, target);
        let got = simulate_step(&cfg, routing, mode, &hw).total_ms();
        if (got - target).abs() > 1e-6 * target {
            return Err(format!("calibrated to {target:.3} but predicts {got:.3}"));
        }
        Ok(())
    });
}

#[test]
fn calibration_clamps_below_model_floor() {
    // a target cheaper than the zero-overhead model cannot be reached;
    // calibration must clamp framework_layer at zero, not go negative
    let cfg = paper::base();
    let routing = Routing::TopK(2);
    let mode = CapacityMode::Times1;
    let mut floor_hw = HardwareModel::v100();
    floor_hw.framework_layer = 0.0;
    let floor = simulate_step(&cfg, routing, mode, &floor_hw).total_ms();
    let hw = HardwareModel::v100().calibrated_to(&cfg, routing, mode, floor * 0.5);
    assert!(hw.framework_layer >= 0.0);
    let got = simulate_step(&cfg, routing, mode, &hw).total_ms();
    assert!((got - floor).abs() < 1e-6 * floor, "clamped model must sit at its floor");
}

#[test]
fn table2_anchor_cell_is_exact() {
    // the shipped Table-2 hardware is anchored on Base/top-2 = 218.2 ms
    let hw = table2_hardware();
    let ms = simulate_step(&paper::base(), Routing::TopK(2), CapacityMode::Times1, &hw)
        .total_ms();
    assert!((ms - 218.2).abs() < 0.5, "anchor drifted: {ms:.2}");
}
