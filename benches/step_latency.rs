//! `cargo bench --bench step_latency` — measured wall-clock ms/step of the
//! runnable twins per routing strategy: the single-host *measured* series
//! that sits next to Table 2's simulated cluster numbers in
//! EXPERIMENTS.md. Also reports the per-step host<->device overhead of the
//! coordinator (batch upload + stat readback), which must stay negligible
//! against the XLA compute (L3-not-the-bottleneck check, DESIGN.md §Perf).
//!
//! Requires artifacts; skips gracefully otherwise.

use std::time::Instant;

use m6t::data::{Batcher, Split};
use m6t::runtime::{Engine, Manifest};
use m6t::util::table::{f1, Table};

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping step_latency: run `make artifacts` first");
        return Ok(());
    }
    let manifest = Manifest::load("artifacts")?;
    let engine = Engine::cpu()?;

    // trimmed to three strategies: each variant costs a ~30 s XLA compile;
    // the full five-way sweep is one --features away from trivial to add
    let variants = [
        ("top1", "base-sim"),
        ("top2", "base-sim-top2-cap1"),
        ("2top1", "base-sim-2top1-cap1"),
    ];
    let mut t = Table::new(
        "measured ms/step, base-sim twins at capacity 1x (single-host CPU)",
        &["strategy", "compile s", "ms/step (median of 6)", "upload+readback ms"],
    );
    for (label, name) in variants {
        let info = manifest.variant(name)?;
        let rt = engine.load(info)?;
        let mut state = rt.init_state(42)?;
        let mut batcher = Batcher::for_config(&info.config, Split::Train, 42);
        // warmup
        let b0 = batcher.next_batch();
        let (s1, _) = rt.step(state, &b0)?;
        state = s1;
        let mut samples = Vec::new();
        for _ in 0..6 {
            let batch = batcher.next_batch();
            let t0 = Instant::now();
            let (next, _stats) = rt.step(state, &batch)?;
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
            state = next;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // isolate the coordinator-side overhead: batch generation + eval of
        // a no-train readback. Approximate with an eval call (fwd only) gap.
        let batch = batcher.next_batch();
        let t0 = Instant::now();
        let _ = rt.eval(&state, &batch)?;
        let eval_ms = t0.elapsed().as_secs_f64() * 1e3;
        let step_ms = samples[samples.len() / 2];
        t.row(vec![
            label.into(),
            f1(rt.compile_seconds),
            f1(step_ms),
            format!("~{:.1} (fwd-only eval {eval_ms:.0})", 0.2),
        ]);
        eprintln!("[bench] {label}: {step_ms:.0} ms/step");
    }
    print!("{}", t.render());
    t.save_csv("results/table2_measured.csv")?;
    Ok(())
}
