//! `cargo bench --bench step_latency` — measured wall-clock ms/step of the
//! native backend per routing strategy, next to the calibrated cluster
//! simulator's prediction for the same variant: the single-host *measured*
//! series that sits beside Table 2's simulated numbers. Uses the same
//! `measure_step_ms` methodology as `m6t bench`, so the two reports agree.
//! Also isolates the coordinator-side overhead (batch generation) so the
//! routing mirror stays visibly the dominant cost.
//!
//! Zero artifacts needed; with `--features pjrt` + artifacts the same
//! harness shape applies to the PJRT engine.

use std::time::Instant;

use m6t::data::{Batcher, Split};
use m6t::runtime::{measure_step_ms, Backend as _, BackendProvider, NativeProvider};
use m6t::util::table::{f1, f2, Table};

fn main() -> anyhow::Result<()> {
    let provider = NativeProvider::new();
    let variants = [
        ("top1", "base-top1"),
        ("top2", "base-top2"),
        ("top4", "base-top4"),
        ("2top1", "base-2top1"),
        ("4top1", "base-4top1"),
    ];
    let mut t = Table::new(
        "measured ms/step, native backend at paper-base geometry",
        &["strategy", "ms/step (median of 8)", "sim cluster ms", "batch-gen ms"],
    );
    for (label, name) in variants {
        let backend = provider.load(name)?;
        let (step_ms, stats) = measure_step_ms(backend.as_ref(), 42, 1, 8)?;
        // coordinator-side overhead: synthesizing one batch
        let mut batcher = Batcher::for_config(&backend.info().config, Split::Train, 42);
        let t0 = Instant::now();
        let _ = batcher.next_batch();
        let gen_ms = t0.elapsed().as_secs_f64() * 1e3;
        t.row(vec![label.into(), f2(step_ms), f1(stats.sim_step_ms), f2(gen_ms)]);
        eprintln!("[bench] {label}: {step_ms:.2} ms/step (sim {:.1} ms)", stats.sim_step_ms);
    }
    print!("{}", t.render());
    t.save_csv("results/table2_measured.csv")?;
    Ok(())
}
