//! `cargo bench --bench paper_tables` — regenerates the paper's analytic
//! tables (Table 1 FLOPs, Table 2 simulated ms/step) and micro-benchmarks
//! the L3 substrates on the hot path: routing mirror, gate softmax, data
//! pipeline, simulator, and the metric sinks.
//!
//! criterion is unavailable offline; this uses the in-tree harness
//! (`m6t::util::bench`) with calibrated iteration counts.

use m6t::cluster::{simulate_step, table2_hardware};
use m6t::config::{paper, CapacityMode, Routing};
use m6t::data::{AttributeSpace, Batcher, Generator, Split};
use m6t::experiments::{table1, table2};
use m6t::moe::router::softmax_gates;
use m6t::moe::{route, RouterSpec};
use m6t::util::bench::{bench, bench_slow};
use m6t::util::rng::Rng;

fn main() {
    println!("== paper tables (analytic) ==\n");
    print!("{}", table1::run(None).render());
    print!("{}", table2::run().render());
    print!("{}", table2::comparison().render());

    println!("\n== L3 micro-benchmarks ==\n");
    let mut results = Vec::new();

    // routing mirror at paper-base geometry: T=1024, E=32, C=40
    let tokens = 1024;
    let experts = 32;
    let mut rng = Rng::new(1);
    let logits: Vec<f32> = (0..tokens * experts).map(|_| rng.normal() as f32).collect();
    let gates1 = softmax_gates(&logits, tokens, experts, 1);
    let gates2 = softmax_gates(&logits, tokens, experts, 2);
    for (name, gates, routing) in [
        ("route/top1/T1024xE32", &gates1, Routing::TopK(1)),
        ("route/top2/T1024xE32", &gates1, Routing::TopK(2)),
        ("route/top4/T1024xE32", &gates1, Routing::TopK(4)),
        ("route/2top1/T1024xE32", &gates2, Routing::Prototype(2)),
        ("route/4top1/T1024xE32", &gates2, Routing::Prototype(4)),
    ] {
        let spec = RouterSpec { routing, num_experts: experts, capacity: 40 };
        results.push(bench(name, || {
            std::hint::black_box(route(gates, tokens, &spec));
        }));
    }

    results.push(bench("softmax_gates/T1024xE32", || {
        std::hint::black_box(softmax_gates(&logits, tokens, experts, 1));
    }));

    // synthetic corpus generator + batcher
    let space = AttributeSpace::new(32, 2048, 7);
    let gen = Generator::new(space, 16, 48, 7);
    let mut idx = 0u64;
    results.push(bench("corpus/example", || {
        idx += 1;
        std::hint::black_box(gen.example(Split::Train, idx));
    }));
    let space2 = AttributeSpace::new(32, 2048, 7);
    let mut batcher = Batcher::new(Generator::new(space2, 16, 48, 7), Split::Train, 8);
    results.push(bench("corpus/batch8", || {
        std::hint::black_box(batcher.next_batch());
    }));

    // cluster simulator over all Table-2 cells
    let hw = table2_hardware();
    let ten_b = paper::ten_b();
    results.push(bench("cluster/simulate_step/10B", || {
        std::hint::black_box(simulate_step(
            &ten_b,
            Routing::Prototype(2),
            CapacityMode::Times1,
            &hw,
        ));
    }));

    // scaling-law fit on a 200-point curve
    let steps: Vec<f64> = (1..200).map(|i| i as f64 * 5.0).collect();
    let losses: Vec<f64> = steps.iter().map(|&s| 2.0 + 5.0 * s.powf(-0.4)).collect();
    results.push(bench_slow("scaling/fit_power_law/200pts", || {
        std::hint::black_box(m6t::scaling::fit_power_law(&steps, &losses));
    }));

    println!();
    for r in &results {
        println!("{}", r.report());
    }
}
