//! `cargo bench --bench routing [-- <tokens>]` — the routing hot path's
//! tracked microbench: tokens/sec of the allocation-free `RoutingEngine`
//! vs the naive `route()` reference over
//! `{top1, top2, top4, 2top1, 4top1} x {E=16, 64} x {tight, ample}`.
//!
//! Shares its suite (and table rendering) with `m6t bench --routing`;
//! both write `BENCH_routing.json` at the repo root so the perf
//! trajectory of the engine is pinned in one place.

use m6t::moe::microbench;

fn main() -> anyhow::Result<()> {
    let tokens: usize = std::env::args()
        .skip(1)
        .find_map(|a| a.parse().ok())
        .unwrap_or(16_384);
    let rows = microbench::run_suite(tokens);
    print!("{}", microbench::render_table(&rows, tokens).render());
    microbench::write_json(&rows, tokens, "BENCH_routing.json")?;
    eprintln!("[bench] wrote BENCH_routing.json");
    Ok(())
}
