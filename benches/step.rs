//! `cargo bench --bench step [-- <steps>]` — the end-to-end sharded-step
//! throughput bench: the fused parallel (worker x layer) grid vs the
//! pre-fusion serial two-pass baseline, measured in the same run over
//! {base, large, xlarge-sim} x {top1, top2, 2top1, 4top1} x D in {1,4,8}.
//!
//! Shares its suite (and table rendering) with `m6t bench --step`; both
//! write `BENCH_step.json` at the repo root so the hot path's end-to-end
//! perf trajectory is pinned in one place.

use m6t::runtime::step_bench;
use m6t::sweep::Engine;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args().skip(1).find_map(|a| a.parse().ok()).unwrap_or(12);
    // timing benches always re-measure; the store still records each cell
    let (rows, _outcome) = step_bench::run_suite(&Engine::new("results").force(true), steps)?;
    print!("{}", step_bench::render_table(&rows, steps).render());
    step_bench::write_json(&rows, steps, "BENCH_step.json")?;
    eprintln!(
        "[bench] xlarge-sim min speedup at D>=4: {:.2}x",
        step_bench::xlarge_min_speedup(&rows)
    );
    eprintln!("[bench] wrote BENCH_step.json");
    Ok(())
}
