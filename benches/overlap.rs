//! `cargo bench --bench overlap [-- <steps>]` — the overlap/topology
//! suite: the link-level, overlap-aware cluster model swept over
//! {base, large, xlarge-sim} x {top1, top2, 2top1} x D in {4, 8, 16} x
//! {flat, hierarchical} topologies.
//!
//! Shares its suite (and table rendering) with `m6t bench --overlap`;
//! both write `BENCH_overlap.json` at the repo root, whose
//! `min_overlap_speedup` field is the CI regression gate.

use m6t::runtime::overlap_bench;
use m6t::sweep::Engine;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args().skip(1).find_map(|a| a.parse().ok()).unwrap_or(12);
    // timing benches always re-measure; the store still records each cell
    let (rows, _outcome) = overlap_bench::run_suite(&Engine::new("results").force(true), steps)?;
    print!("{}", overlap_bench::render_table(&rows, steps).render());
    overlap_bench::write_json(&rows, steps, "BENCH_overlap.json")?;
    eprintln!(
        "[bench] min overlap speedup: {:.2}x, max bottleneck link share: {:.2}",
        overlap_bench::min_overlap_speedup(&rows),
        overlap_bench::max_bottleneck_link_share(&rows)
    );
    eprintln!("[bench] wrote BENCH_overlap.json");
    Ok(())
}
