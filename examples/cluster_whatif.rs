//! What-if explorer for the cluster simulator: sweep worker counts,
//! capacity factors, and routing strategies at paper scale and print the
//! simulated step-time breakdowns — the tool you would use to plan a
//! 480-GPU run like the paper's §4 before buying the GPUs.
//!
//! ```bash
//! cargo run --release --example cluster_whatif -- [model]   # base|10B|100B|250B|1T
//! ```

use anyhow::Result;
use m6t::cluster::{simulate_step, table2_hardware};
use m6t::config::{paper, CapacityMode, Routing};
use m6t::util::table::{f1, Table};

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "1T".to_string());
    let cfg = paper::by_name(&model)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {model:?} (base|10B|100B|250B|1T)"))?;
    let hw = table2_hardware();

    println!(
        "model {} — {:.1}B params on {} workers\n",
        cfg.name,
        cfg.param_count() as f64 / 1e9,
        cfg.workers
    );

    let mut t = Table::new(
        format!("simulated step breakdown ({model}, capacity 1x)"),
        &["strategy", "gate", "a2a", "expert", "disp/comb", "allreduce", "total ms"],
    );
    for r in [
        Routing::TopK(1),
        Routing::TopK(2),
        Routing::TopK(4),
        Routing::Prototype(2),
        Routing::Prototype(4),
    ] {
        let s = simulate_step(&cfg, r, CapacityMode::Times1, &hw);
        t.row(vec![
            r.name(),
            f1(s.gating_ms),
            f1(s.a2a_ms),
            f1(s.expert_ms),
            f1(s.dispatch_combine_ms),
            f1(s.allreduce_ms),
            f1(s.total_ms()),
        ]);
    }
    print!("{}", t.render());

    // capacity-factor sweep: the paper's gamma=1.25 buffer vs alternatives
    let mut c = Table::new(
        "capacity-factor sweep (top-2, capacity kx)",
        &["gamma", "expert ms", "a2a ms", "total ms"],
    );
    for gamma in [1.0, 1.25, 1.5, 2.0] {
        let mut cfg2 = cfg.clone();
        cfg2.capacity_factor = gamma;
        let s = simulate_step(&cfg2, Routing::TopK(2), CapacityMode::TimesK, &hw);
        c.row(vec![
            format!("{gamma:.2}"),
            f1(s.expert_ms),
            f1(s.a2a_ms),
            f1(s.total_ms()),
        ]);
    }
    print!("{}", c.render());

    // worker scaling: how step time moves from 8 to 480 workers
    let mut w = Table::new(
        "worker scaling (2top1, capacity 1x)",
        &["workers", "a2a ms", "allreduce ms", "total ms"],
    );
    for workers in [8usize, 16, 64, 128, 240, 480] {
        let mut cfg3 = cfg.clone();
        cfg3.workers = workers;
        let s = simulate_step(&cfg3, Routing::Prototype(2), CapacityMode::Times1, &hw);
        w.row(vec![
            workers.to_string(),
            f1(s.a2a_ms),
            f1(s.allreduce_ms),
            f1(s.total_ms()),
        ]);
    }
    print!("{}", w.render());
    Ok(())
}
