//! End-to-end validation (DESIGN.md §4): train the ~100M-parameter
//! `e2e-100m` config through the full three-layer stack — rust data
//! pipeline -> AOT-compiled JAX+Pallas train step on PJRT -> metrics —
//! and log the loss curve for EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example train_e2e -- [steps]   # default 300
//! ```

use anyhow::Result;
use m6t::coordinator::{TrainOptions, Trainer};
use m6t::runtime::{Engine, Manifest};
use m6t::util::table::Table;

fn main() -> Result<()> {
    let steps: i64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let manifest = Manifest::load("artifacts")?;
    let engine = Engine::cpu()?;
    let info = manifest.variant("e2e-100m")?;
    eprintln!(
        "[e2e] {} — {:.1}M params, {} layers, {} experts, {} routing, state {:.0} MB device-resident",
        info.name,
        info.param_count as f64 / 1e6,
        info.config.layers,
        info.config.num_experts,
        info.config.routing.name(),
        info.state_bytes() as f64 / 1e6,
    );
    let runtime = engine.load(info)?;
    eprintln!("[e2e] compiled in {:.1}s", runtime.compile_seconds);

    let opts = TrainOptions {
        steps,
        eval_every: (steps / 6).max(1),
        eval_batches: 8,
        metrics_dir: Some("results/metrics".into()),
        ..Default::default()
    };
    let trainer = Trainer::new(&engine, runtime, opts);
    let (outcome, state) = trainer.train()?;

    // summary table -> results/e2e_loss_curve.csv
    let mut t = Table::new("E2E 100M loss curve", &["step", "loss", "ms"]);
    for r in outcome.log.records.iter().filter(|r| r.step % 10 == 0) {
        t.row(vec![
            r.step.to_string(),
            format!("{:.4}", r.loss),
            format!("{:.0}", r.ms_per_step),
        ]);
    }
    t.save_csv("results/e2e_loss_curve.csv")?;
    let mut ev = Table::new("E2E 100M eval PPL", &["step", "ppl"]);
    for (s, p) in &outcome.evals {
        ev.row(vec![s.to_string(), format!("{p:.2}")]);
    }
    ev.save_csv("results/e2e_evals.csv")?;
    print!("{}", ev.render());

    let ck = trainer.snapshot(&state)?;
    ck.save("results/e2e-100m.ckpt")?;
    println!(
        "final loss {:.4}, eval PPL {:.2}, mean {:.0} ms/step; checkpoint + CSVs in results/",
        outcome.log.tail_loss(20),
        outcome.evals.last().map(|&(_, p)| p).unwrap_or(f64::NAN),
        outcome.log.records.iter().map(|r| r.ms_per_step).sum::<f64>()
            / outcome.log.records.len().max(1) as f64,
    );
    Ok(())
}
