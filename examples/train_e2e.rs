//! End-to-end validation: train the ~100M-parameter `e2e-100m` config
//! through the full stack — rust data pipeline -> backend train step ->
//! metrics — and log the loss curve. Runs on the native backend by
//! default; with `--features pjrt` + artifacts the same flow executes the
//! AOT-compiled JAX+Pallas step instead (DESIGN.md §Backends).
//!
//! ```bash
//! cargo run --release --example train_e2e -- [steps]   # default 300
//! ```

use anyhow::Result;
use m6t::coordinator::{TrainOptions, Trainer};
use m6t::runtime::{BackendProvider, NativeProvider};
use m6t::util::table::Table;

fn main() -> Result<()> {
    let steps: i64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let provider = NativeProvider::new();
    let info = provider.info("e2e-100m")?;
    eprintln!(
        "[e2e] {} — {:.1}M params, {} layers, {} experts, {} routing, state {:.0} kB host-resident",
        info.name,
        info.param_count as f64 / 1e6,
        info.config.layers,
        info.config.num_experts,
        info.config.routing.name(),
        info.state_bytes() as f64 / 1e3,
    );

    let opts = TrainOptions {
        steps,
        eval_every: (steps / 6).max(1),
        eval_batches: 8,
        metrics_dir: Some("results/metrics".into()),
        ..Default::default()
    };
    let trainer = Trainer::new(provider.load("e2e-100m")?, opts);
    let (outcome, state) = trainer.train()?;

    // summary table -> results/e2e_loss_curve.csv
    let mut t = Table::new("E2E 100M loss curve", &["step", "loss", "ms"]);
    for r in outcome.log.records.iter().filter(|r| r.step % 10 == 0) {
        t.row(vec![
            r.step.to_string(),
            format!("{:.4}", r.loss),
            format!("{:.2}", r.ms_per_step),
        ]);
    }
    t.save_csv("results/e2e_loss_curve.csv")?;
    let mut ev = Table::new("E2E 100M eval PPL", &["step", "ppl"]);
    for (s, p) in &outcome.evals {
        ev.row(vec![s.to_string(), format!("{p:.2}")]);
    }
    ev.save_csv("results/e2e_evals.csv")?;
    print!("{}", ev.render());

    let ck = trainer.snapshot(&state)?;
    ck.save("results/e2e-100m.ckpt")?;
    println!(
        "final loss {:.4}, eval PPL {:.2}, mean {:.2} ms/step; checkpoint + CSVs in results/",
        outcome.log.tail_loss(20),
        outcome.evals.last().map(|&(_, p)| p).unwrap_or(f64::NAN),
        outcome.log.records.iter().map(|r| r.ms_per_step).sum::<f64>()
            / outcome.log.records.len().max(1) as f64,
    );
    Ok(())
}
