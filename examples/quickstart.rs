//! Quickstart: load a variant, train briefly, evaluate, inspect balance.
//! Runs on the pure-Rust native backend — no artifacts needed.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use m6t::coordinator::{TrainOptions, Trainer};
use m6t::runtime::{BackendProvider, NativeProvider};

fn main() -> Result<()> {
    // 1. the built-in native registry: every runnable variant
    let provider = NativeProvider::new();
    println!("{} runnable variants", provider.names().len());

    // 2. one loaded backend
    let info = provider.info("base-sim")?;
    println!(
        "base-sim: {:.1}M params, {} experts, routing {}, capacity {}",
        info.param_count as f64 / 1e6,
        info.config.num_experts,
        info.config.routing.name(),
        info.capacity,
    );

    // 3. train 30 steps on the synthetic multimodal corpus
    let opts = TrainOptions { steps: 30, verbose: false, ..Default::default() };
    let trainer = Trainer::new(provider.load("base-sim")?, opts);
    let (outcome, state) = trainer.train()?;
    println!(
        "loss {:.4} -> {:.4} over {} steps",
        outcome.log.records.first().map(|r| r.loss).unwrap_or(f64::NAN),
        outcome.log.tail_loss(5),
        outcome.log.records.len()
    );

    // 4. held-out PPL (the paper's downstream metric) + expert balance
    let ppl = trainer.eval_ppl(&state, 8)?;
    println!("eval PPL: {ppl:.2}");
    if let Some(last) = outcome.log.last() {
        println!(
            "per-layer load c_v: {:?}",
            last.cv_per_layer.iter().map(|c| format!("{c:.2}")).collect::<Vec<_>>()
        );
        println!("dropped tokens last step: {}", last.dropped);
        println!("simulated cluster step time: {:.1} ms", last.sim_ms);
    }
    Ok(())
}
