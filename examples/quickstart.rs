//! Quickstart: load a variant, train briefly, evaluate, inspect balance.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use m6t::coordinator::{TrainOptions, Trainer};
use m6t::runtime::{Engine, Manifest};

fn main() -> Result<()> {
    // 1. the artifact manifest: every variant python lowered for us
    let manifest = Manifest::load("artifacts")?;
    println!("{} runnable variants", manifest.variants.len());

    // 2. a PJRT CPU engine + one compiled variant
    let engine = Engine::cpu()?;
    let info = manifest.variant("base-sim")?;
    println!(
        "base-sim: {:.1}M params, {} experts, routing {}, capacity {}",
        info.param_count as f64 / 1e6,
        info.config.num_experts,
        info.config.routing.name(),
        info.capacity,
    );
    let runtime = engine.load(info)?;
    println!("compiled in {:.1}s on {}", runtime.compile_seconds, engine.platform());

    // 3. train 30 steps on the synthetic multimodal corpus
    let opts = TrainOptions { steps: 30, verbose: false, ..Default::default() };
    let trainer = Trainer::new(&engine, runtime, opts);
    let (outcome, state) = trainer.train()?;
    println!(
        "loss {:.4} -> {:.4} over {} steps",
        outcome.log.records.first().map(|r| r.loss).unwrap_or(f64::NAN),
        outcome.log.tail_loss(5),
        outcome.log.records.len()
    );

    // 4. held-out PPL (the paper's downstream metric) + expert balance
    let ppl = trainer.eval_ppl(&state, 8)?;
    println!("eval PPL: {ppl:.2}");
    if let Some(last) = outcome.log.last() {
        println!(
            "per-layer load c_v: {:?}",
            last.cv_per_layer.iter().map(|c| format!("{c:.2}")).collect::<Vec<_>>()
        );
        println!("dropped tokens last step: {}", last.dropped);
    }
    Ok(())
}
