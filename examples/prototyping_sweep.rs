//! Expert-prototyping sweep (the paper's §3.3 in miniature): trains top-1,
//! top-2 and 2-top-1 at equal FLOPs (capacity 1x) and prints convergence +
//! wall-clock side by side — the effectiveness/efficiency trade-off the
//! paper's Tables 2/3 quantify. Native backend, zero artifacts.
//!
//! ```bash
//! cargo run --release --example prototyping_sweep -- [steps]   # default 120
//! ```

use anyhow::Result;
use m6t::coordinator::{TrainOptions, Trainer};
use m6t::runtime::{BackendProvider, NativeProvider};
use m6t::util::table::{f2, f3, Table};

fn main() -> Result<()> {
    let steps: i64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(120);
    let provider = NativeProvider::new();

    let variants = ["base-sim", "base-sim-top2-cap1", "base-sim-2top1-cap1"];
    let mut table = Table::new(
        "prototyping sweep (equal-FLOPs capacity 1x)",
        &["variant", "final loss", "eval PPL", "sim ms/step", "dropped/step"],
    );
    for name in variants {
        let opts = TrainOptions { steps, verbose: false, ..Default::default() };
        let trainer = Trainer::new(provider.load(name)?, opts);
        let (outcome, _state) = trainer.train()?;
        let n = outcome.log.records.len().max(1) as f64;
        table.row(vec![
            name.into(),
            f3(outcome.log.tail_loss(20)),
            f2(outcome.evals.last().map(|&(_, p)| p).unwrap_or(f64::NAN)),
            f2(outcome.log.last().map(|r| r.sim_ms).unwrap_or(f64::NAN)),
            f2(outcome.log.records.iter().map(|r| r.dropped).sum::<f64>() / n),
        ]);
        eprintln!("[sweep] {name} done");
    }
    print!("{}", table.render());
    table.save_csv("results/prototyping_sweep.csv")?;
    Ok(())
}
