# Convenience targets; tier-1 verify is `cargo build --release && cargo test -q`.

.PHONY: build test fmt lint lint-unsafe miri tsan run report artifacts smoke bench-step bench-overlap bench-ffn bench-elastic bench-placement bench-serve sweep sweep-gc

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt --check

# Static unsafe-budget gate: scans the workspace for `unsafe` tokens and
# checks them against rust/unsafe_allowlist.txt (every site needs an
# adjacent `// SAFETY:` comment, and the only budgeted file is
# rust/src/util/shard.rs). Also runs as a plain unit test in `make test`.
lint-unsafe:
	cargo run --release -- lint-unsafe

lint: lint-unsafe
	cargo clippy -- -D warnings
	cargo fmt --check

# Miri over the concurrency-relevant subset (tests shrink their sizes
# under cfg(miri)). Needs a nightly toolchain with the miri component.
miri:
	MIRIFLAGS="-Zmiri-disable-isolation -Zmiri-ignore-leaks" \
		cargo +nightly miri test -q -p m6t --lib -- \
		util::shard util::pool moe::engine moe::ffn moe::fused moe::dispatch
	MIRIFLAGS="-Zmiri-disable-isolation -Zmiri-ignore-leaks" \
		cargo +nightly miri test -q -p m6t --test shard_views

# ThreadSanitizer smoke over the cross-thread determinism tests. Needs a
# nightly toolchain with the rust-src component (for -Zbuild-std).
tsan:
	RUSTFLAGS="-Zsanitizer=thread" \
		cargo +nightly test -Zbuild-std --target x86_64-unknown-linux-gnu -q \
		-p m6t --test pool_determinism --test shard_views

run:
	cargo run --release -- run --variant base-top2

report:
	cargo run --release -- report

# End-to-end step throughput: fused (worker x layer) grid vs the serial
# two-pass baseline, written to BENCH_step.json (see DESIGN.md on how to
# read it).
bench-step:
	cargo run --release -- bench --step

# Link-level overlap-aware cluster model vs the serial aggregate, over
# flat and hierarchical topologies, written to BENCH_overlap.json (see
# DESIGN.md on how to read it).
bench-overlap:
	cargo run --release -- bench --overlap

# Native expert-FFN kernels: cache-tiled fwd/bwd vs the naive loop-order
# baseline, written to BENCH_ffn.json (see DESIGN.md on how to read it).
bench-ffn:
	cargo run --release -- bench --ffn

# Elastic-capacity grid (skewed base-twin x D in {4, 8}): static vs
# elastic drop rates at the same slot budget. Rides in the dispatch
# suite's BENCH_dispatch.json (`elastic_rows`, `max_elastic_drop_delta`).
bench-elastic:
	cargo run --release -- sweep elastic

# Topology-aware placement grid ({base, large-sim} x D in {4, 8},
# hierarchical): greedy+swap search vs the identity layout. Rides in the
# overlap suite's BENCH_overlap.json (`placement_rows`,
# `min_placement_gain`, `max_placement_share_delta`).
bench-placement:
	cargo run --release -- sweep placement

# Open-loop serving simulation: seeded arrival traces ({poisson, bursty,
# diurnal} x D in {1, 4, 8} x load x skew x drain) through the
# continuous-batching admission loop, priced by the profiled sharded
# engine. Writes BENCH_serve.json (`max_p99_over_slo`,
# `min_goodput_share`; see DESIGN.md §"Serving runtime & open-loop
# simulation").
bench-serve:
	cargo run --release -- serve-sim

# Run every builtin bench family through the sweep engine's
# content-addressed store (results/store): completed cells are served from
# the store, so a re-run after an interruption only executes what's
# missing. See DESIGN.md §"Sweep driver & experiment store".
sweep:
	cargo run --release -- sweep dispatch
	cargo run --release -- sweep step
	cargo run --release -- sweep overlap
	cargo run --release -- sweep ffn
	cargo run --release -- sweep elastic
	cargo run --release -- sweep placement
	cargo run --release -- sweep serve

# Prune store cells whose address no longer appears in any builtin spec
# (training runs are never scanned by a bench-only gc).
sweep-gc:
	cargo run --release -- sweep gc

# `artifacts` is a documented no-op stub. The AOT pipeline
# (python/compile/aot.py -> HLO text + artifacts/manifest.json) feeds the
# PJRT engine, which is gated behind the `pjrt` cargo feature and needs
# the vendored patched `xla` crate — not shipped in this offline
# environment (third_party/xla-stub stands in so the feature still
# compiles). See DESIGN.md §Backends. Everything in tier-1, the CLI, the
# examples, and the benches runs without artifacts on the native backend.
artifacts:
	@echo "artifacts: no-op — the PJRT/XLA artifact pipeline requires the vendored 'xla' crate."
	@echo "Type-check the engine with: cargo build --features pjrt   (see DESIGN.md §Backends)"

smoke:
	cargo run --release --features pjrt --bin smoke
